//! Deterministic, splittable PRNG (xoshiro256** core) used everywhere a
//! seed appears: dataset synthesis, topology rotation shuffles, network
//! noise, property tests.  Determinism is a hard requirement — every
//! experiment in EXPERIMENTS.md records its seed.

#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via splitmix64 expansion so nearby seeds give unrelated streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng {
            s: [next(), next(), next(), next()],
        }
    }

    /// Derive an independent stream (e.g. one per rank).
    pub fn split(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0xA24BAED4963EE407))
    }

    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        // Lemire's multiply-shift rejection-free-enough variant
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform f32 in [0, 1).
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 / (1u64 << 24) as f32
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = (self.f64()).max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// A random permutation of 0..n.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut v: Vec<usize> = (0..n).collect();
        self.shuffle(&mut v);
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(43);
        assert_ne!(Rng::new(42).next_u64(), c.next_u64());
    }

    #[test]
    fn below_in_range_and_covers() {
        let mut r = Rng::new(1);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let v = r.below(7);
            assert!(v < 7);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn permutation_is_bijection() {
        let mut r = Rng::new(9);
        for n in [1usize, 2, 5, 33, 128] {
            let p = r.permutation(n);
            let mut seen = vec![false; n];
            for &v in &p {
                assert!(!seen[v]);
                seen[v] = true;
            }
        }
    }

    #[test]
    fn normal_moments_sane() {
        let mut r = Rng::new(5);
        let xs: Vec<f64> = (0..20000).map(|_| r.normal()).collect();
        let m = crate::util::mean(&xs);
        let s = crate::util::stddev(&xs);
        assert!(m.abs() < 0.05, "mean {m}");
        assert!((s - 1.0).abs() < 0.05, "std {s}");
    }

    #[test]
    fn f32_f64_in_unit_interval() {
        let mut r = Rng::new(11);
        for _ in 0..1000 {
            let a = r.f64();
            let b = r.f32();
            assert!((0.0..1.0).contains(&a));
            assert!((0.0..1.0).contains(&b));
        }
    }
}
