//! Supporting infrastructure hand-rolled for the offline build
//! environment (no serde / clap / criterion / proptest crates available):
//! seeded RNG, minimal JSON codec, micro-benchmark harness, property-test
//! harness and a tiny argv parser.

pub mod args;
pub mod bench;
pub mod json;
pub mod prop;
pub mod rng;

pub use rng::Rng;

/// ceil(log2(p)) — the paper's diffusion horizon; 0 for p <= 1.
pub fn ceil_log2(p: usize) -> usize {
    if p <= 1 {
        0
    } else {
        usize::BITS as usize - (p - 1).leading_zeros() as usize
    }
}

/// Mean of a slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// FNV-1a 64-bit hash — stable across platforms and runs (unlike
/// `std::hash`, which is seeded per-process).  Used for config content
/// hashes (`RunConfig::content_hash`) and model-bit checksums
/// (`RunResult::param_hash`).
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Poll `f` until it yields a value, panicking after a 5 s deadline —
/// the one shared replacement for the `loop { …; yield_now() }`
/// busy-wait blocks tests used to copy-paste around non-blocking
/// `test()`/`test_raw()` calls.  Sleeps 1 ms between attempts, so a
/// loaded machine gets real time instead of a flaky spin count and idle
/// cores aren't burned while waiting.
pub fn deadline_poll<T>(what: &str, mut f: impl FnMut() -> Option<T>) -> T {
    let deadline =
        std::time::Instant::now() + std::time::Duration::from_secs(5);
    loop {
        if let Some(v) = f() {
            return v;
        }
        assert!(
            std::time::Instant::now() < deadline,
            "deadline_poll: {what} did not complete within 5s"
        );
        std::thread::sleep(std::time::Duration::from_millis(1));
    }
}

/// Sample standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64)
        .sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ceil_log2_matches_table() {
        let cases = [
            (0, 0),
            (1, 0),
            (2, 1),
            (3, 2),
            (4, 2),
            (5, 3),
            (8, 3),
            (9, 4),
            (16, 4),
            (128, 7),
            (1000, 10),
            (1024, 10),
        ];
        for (p, want) in cases {
            assert_eq!(ceil_log2(p), want, "p={p}");
        }
    }

    #[test]
    fn fnv1a64_known_vectors() {
        // reference values from the FNV spec
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a64(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_ne!(fnv1a64(b"ab"), fnv1a64(b"ba"));
    }

    #[test]
    fn mean_stddev_basic() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert!((stddev(&[1.0, 2.0, 3.0]) - 1.0).abs() < 1e-12);
        assert_eq!(stddev(&[5.0]), 0.0);
        assert_eq!(mean(&[]), 0.0);
    }
}
