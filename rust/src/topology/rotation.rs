//! Partner rotation (paper §4.5.1).
//!
//! Dissemination exchange repeats its partners every ⌈log₂ p⌉ steps, so
//! *direct* diffusion is limited to log(p)/p of the ranks.  The paper's
//! fix: p random shuffles of the communicator; after every ⌈log₂ p⌉
//! steps, advance to the next shuffled communicator and rebuild the
//! virtual dissemination topology on it.
//!
//! The eager form of that table is O(p²) integers (p+1 permutations plus
//! inverses) rebuilt *per rank* — at p = 1024 that is ~8 M usizes per
//! worker before the first step runs.  Epochs are therefore drawn
//! lazily: the RNG stream is consumed strictly in epoch order on first
//! use and each epoch's (perm, inverse) pair is memoised, so the table
//! is bit-identical to the eager one (pinned by a test below) while a
//! run of s steps only ever materialises ⌈s/⌈log₂ p⌉⌉ epochs.
//!
//! `Rotation` wraps any inner topology: ranks are mapped through the
//! active permutation before the inner exchange formula is applied.

use super::{Exchange, Topology};
use crate::util::{ceil_log2, Rng};
use std::sync::{Mutex, OnceLock};

pub struct Rotation<T: Topology> {
    inner: T,
    /// slots[e] = (perm, pos) for epoch e, drawn on first use.
    /// perm[v] = physical rank at virtual position v;
    /// pos[r] = virtual position of physical rank r (the inverse).
    slots: Vec<OnceLock<Epoch>>,
    /// The RNG stream + the next epoch index it will draw.  Epochs are
    /// always drawn in order 0, 1, 2, … regardless of which epoch is
    /// requested first, so the stream consumption (and hence every
    /// permutation) matches the historical eager construction exactly.
    gen: Mutex<Gen>,
    period: usize,
}

struct Epoch {
    perm: Vec<usize>,
    pos: Vec<usize>,
}

struct Gen {
    rng: Rng,
    next: usize,
}

impl<T: Topology> Rotation<T> {
    pub fn new(inner: T, seed: u64) -> Self {
        let p = inner.size();
        let period = ceil_log2(p).max(1);
        Rotation {
            inner,
            // epoch 0 is the identity (matches the paper: rotation kicks
            // in after the first log(p) steps); then p random shuffles
            slots: (0..p + 1).map(|_| OnceLock::new()).collect(),
            gen: Mutex::new(Gen {
                rng: Rng::new(seed),
                next: 0,
            }),
            period,
        }
    }

    /// Which communicator epoch is active at `step`.
    pub fn epoch(&self, step: usize) -> usize {
        (step / self.period) % self.slots.len()
    }

    pub fn num_epochs(&self) -> usize {
        self.slots.len()
    }

    /// Epoch `e`'s state, drawing any not-yet-materialised epochs up to
    /// `e` in stream order first.
    fn epoch_state(&self, e: usize) -> &Epoch {
        if let Some(s) = self.slots[e].get() {
            return s;
        }
        let p = self.inner.size();
        let mut gen = self.gen.lock().unwrap();
        while gen.next <= e {
            let i = gen.next;
            let perm: Vec<usize> = if i == 0 {
                (0..p).collect()
            } else {
                gen.rng.permutation(p)
            };
            let mut pos = vec![0usize; p];
            for (v, &r) in perm.iter().enumerate() {
                pos[r] = v;
            }
            // only the holder of the gen lock ever sets a slot
            let _ = self.slots[i].set(Epoch { perm, pos });
            gen.next = i + 1;
        }
        self.slots[e].get().expect("drawn above")
    }

    /// Epoch `e`'s communicator ordering: `perm[v]` is the physical
    /// rank at virtual position v.  The membership layer rebuilds a
    /// degraded-view partner formula over this ordering with dead ranks
    /// filtered out (`membership::collapsed_exchange`), preserving the
    /// rotation's diffusion pattern among the survivors.
    pub fn perm(&self, e: usize) -> &[usize] {
        &self.epoch_state(e).perm
    }

    pub fn inner(&self) -> &T {
        &self.inner
    }
}

impl<T: Topology> Topology for Rotation<T> {
    fn size(&self) -> usize {
        self.inner.size()
    }

    fn exchange(&self, rank: usize, step: usize) -> Exchange {
        let st = self.epoch_state(self.epoch(step));
        let v = st.pos[rank];
        let ex = self.inner.exchange(v, step);
        Exchange {
            send_to: st.perm[ex.send_to],
            recv_from: st.perm[ex.recv_from],
        }
    }

    fn diffusion_steps(&self) -> usize {
        self.inner.diffusion_steps()
    }

    fn name(&self) -> &'static str {
        "rotated"
    }
}

#[cfg(test)]
mod tests {
    use super::super::{check_balanced, Dissemination};
    use super::*;
    use std::collections::HashSet;

    #[test]
    fn stays_balanced_under_rotation() {
        for p in [4usize, 7, 16, 33] {
            let t = Rotation::new(Dissemination::new(p), 42);
            for step in 0..6 * t.period {
                check_balanced(&t, step).unwrap();
            }
        }
    }

    #[test]
    fn epoch_advances_every_log_p_steps() {
        let t = Rotation::new(Dissemination::new(16), 1);
        assert_eq!(t.period, 4);
        assert_eq!(t.epoch(0), 0);
        assert_eq!(t.epoch(3), 0);
        assert_eq!(t.epoch(4), 1);
        assert_eq!(t.epoch(8), 2);
    }

    #[test]
    fn first_epoch_is_identity() {
        let p = 8;
        let rot = Rotation::new(Dissemination::new(p), 9);
        let plain = Dissemination::new(p);
        for step in 0..rot.period {
            for r in 0..p {
                assert_eq!(rot.exchange(r, step), plain.exchange(r, step));
            }
        }
    }

    #[test]
    fn rotation_widens_direct_partner_set() {
        // §4.5.1 motivation: without rotation rank 0 only ever meets
        // log(p) distinct partners; with rotation it meets many more.
        let p = 32;
        let plain = Dissemination::new(p);
        let rot = Rotation::new(Dissemination::new(p), 3);
        let horizon = 40 * rot.period;
        let direct = |t: &dyn Topology| {
            let mut s = HashSet::new();
            for step in 0..horizon {
                let e = t.exchange(0, step);
                s.insert(e.send_to);
                s.insert(e.recv_from);
            }
            s.len()
        };
        let d_plain = direct(&plain);
        let d_rot = direct(&rot);
        assert!(d_plain <= 2 * crate::util::ceil_log2(p));
        assert!(
            d_rot > 2 * d_plain,
            "rotation gave {d_rot} direct partners vs {d_plain} plain"
        );
    }

    #[test]
    fn all_perms_are_bijections() {
        let rot = Rotation::new(Dissemination::new(13), 77);
        for e in 0..rot.num_epochs() {
            let s: HashSet<_> = rot.perm(e).iter().collect();
            assert_eq!(s.len(), 13);
        }
    }

    #[test]
    fn lazy_epochs_match_eager_table_bit_for_bit() {
        // the historical eager construction, replicated inline: identity,
        // then p permutations drawn from one sequential stream
        let (p, seed) = (13usize, 77u64);
        let mut rng = Rng::new(seed);
        let mut eager = vec![(0..p).collect::<Vec<_>>()];
        for _ in 0..p {
            eager.push(rng.permutation(p));
        }
        let rot = Rotation::new(Dissemination::new(p), seed);
        assert_eq!(rot.num_epochs(), p + 1);
        // request epochs out of order: memoisation must not let access
        // order perturb the stream
        for &e in &[5usize, 2, 13, 0, 7, 5, 12, 1] {
            assert_eq!(rot.perm(e), &eager[e][..], "epoch {e}");
        }
        for (e, want) in eager.iter().enumerate() {
            assert_eq!(rot.perm(e), &want[..], "epoch {e}");
        }
    }
}
