//! Partner rotation (paper §4.5.1).
//!
//! Dissemination exchange repeats its partners every ⌈log₂ p⌉ steps, so
//! *direct* diffusion is limited to log(p)/p of the ranks.  The paper's
//! fix: precompute p random shuffles of the communicator at startup;
//! after every ⌈log₂ p⌉ steps, advance to the next shuffled communicator
//! and rebuild the virtual dissemination topology on it.  Cost is
//! amortised to ~0 (all permutations precomputed here, as in the paper).
//!
//! `Rotation` wraps any inner topology: ranks are mapped through the
//! active permutation before the inner exchange formula is applied.

use super::{Exchange, Topology};
use crate::util::{ceil_log2, Rng};

pub struct Rotation<T: Topology> {
    inner: T,
    /// perms[e][v] = physical rank at virtual position v, epoch e.
    perms: Vec<Vec<usize>>,
    /// inverse: pos[e][r] = virtual position of physical rank r.
    pos: Vec<Vec<usize>>,
    period: usize,
}

impl<T: Topology> Rotation<T> {
    pub fn new(inner: T, seed: u64) -> Self {
        let p = inner.size();
        let mut rng = Rng::new(seed);
        // epoch 0 is the identity (matches the paper: rotation kicks in
        // after the first log(p) steps); then p random shuffles.
        let mut perms = vec![(0..p).collect::<Vec<_>>()];
        for _ in 0..p {
            perms.push(rng.permutation(p));
        }
        let pos = perms
            .iter()
            .map(|perm| {
                let mut inv = vec![0usize; p];
                for (v, &r) in perm.iter().enumerate() {
                    inv[r] = v;
                }
                inv
            })
            .collect();
        let period = ceil_log2(p).max(1);
        Rotation {
            inner,
            perms,
            pos,
            period,
        }
    }

    /// Which communicator epoch is active at `step`.
    pub fn epoch(&self, step: usize) -> usize {
        (step / self.period) % self.perms.len()
    }

    pub fn num_epochs(&self) -> usize {
        self.perms.len()
    }

    /// Epoch `e`'s communicator ordering: `perm[v]` is the physical
    /// rank at virtual position v.  The membership layer rebuilds a
    /// degraded-view partner formula over this ordering with dead ranks
    /// filtered out (`membership::collapsed_exchange`), preserving the
    /// rotation's diffusion pattern among the survivors.
    pub fn perm(&self, e: usize) -> &[usize] {
        &self.perms[e]
    }

    pub fn inner(&self) -> &T {
        &self.inner
    }
}

impl<T: Topology> Topology for Rotation<T> {
    fn size(&self) -> usize {
        self.inner.size()
    }

    fn exchange(&self, rank: usize, step: usize) -> Exchange {
        let e = self.epoch(step);
        let v = self.pos[e][rank];
        let ex = self.inner.exchange(v, step);
        Exchange {
            send_to: self.perms[e][ex.send_to],
            recv_from: self.perms[e][ex.recv_from],
        }
    }

    fn diffusion_steps(&self) -> usize {
        self.inner.diffusion_steps()
    }

    fn name(&self) -> &'static str {
        "rotated"
    }
}

#[cfg(test)]
mod tests {
    use super::super::{check_balanced, Dissemination};
    use super::*;
    use std::collections::HashSet;

    #[test]
    fn stays_balanced_under_rotation() {
        for p in [4usize, 7, 16, 33] {
            let t = Rotation::new(Dissemination::new(p), 42);
            for step in 0..6 * t.period {
                check_balanced(&t, step).unwrap();
            }
        }
    }

    #[test]
    fn epoch_advances_every_log_p_steps() {
        let t = Rotation::new(Dissemination::new(16), 1);
        assert_eq!(t.period, 4);
        assert_eq!(t.epoch(0), 0);
        assert_eq!(t.epoch(3), 0);
        assert_eq!(t.epoch(4), 1);
        assert_eq!(t.epoch(8), 2);
    }

    #[test]
    fn first_epoch_is_identity() {
        let p = 8;
        let rot = Rotation::new(Dissemination::new(p), 9);
        let plain = Dissemination::new(p);
        for step in 0..rot.period {
            for r in 0..p {
                assert_eq!(rot.exchange(r, step), plain.exchange(r, step));
            }
        }
    }

    #[test]
    fn rotation_widens_direct_partner_set() {
        // §4.5.1 motivation: without rotation rank 0 only ever meets
        // log(p) distinct partners; with rotation it meets many more.
        let p = 32;
        let plain = Dissemination::new(p);
        let rot = Rotation::new(Dissemination::new(p), 3);
        let horizon = 40 * rot.period;
        let direct = |t: &dyn Topology| {
            let mut s = HashSet::new();
            for step in 0..horizon {
                let e = t.exchange(0, step);
                s.insert(e.send_to);
                s.insert(e.recv_from);
            }
            s.len()
        };
        let d_plain = direct(&plain);
        let d_rot = direct(&rot);
        assert!(d_plain <= 2 * crate::util::ceil_log2(p));
        assert!(
            d_rot > 2 * d_plain,
            "rotation gave {d_rot} direct partners vs {d_plain} plain"
        );
    }

    #[test]
    fn all_perms_are_bijections() {
        let rot = Rotation::new(Dissemination::new(13), 77);
        for perm in &rot.perms {
            let s: HashSet<_> = perm.iter().collect();
            assert_eq!(s.len(), 13);
        }
    }
}
