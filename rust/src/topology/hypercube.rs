//! Hypercube exchange (paper §4.4.1): at step k, rank i pairs with
//! i ⊕ 2^k — a *pairwise* exchange (send and recv partner coincide), so
//! each step diffuses gradients from exactly one partner.  Requires p to
//! be a power of two; the paper considers it and prefers dissemination.

use super::{Exchange, Topology};
use crate::util::ceil_log2;

#[derive(Clone, Debug)]
pub struct Hypercube {
    p: usize,
    dims: usize,
}

impl Hypercube {
    pub fn new(p: usize) -> Self {
        assert!(p.is_power_of_two(), "hypercube requires power-of-two p, got {p}");
        Hypercube {
            p,
            dims: ceil_log2(p).max(1),
        }
    }
}

impl Topology for Hypercube {
    fn size(&self) -> usize {
        self.p
    }

    fn exchange(&self, rank: usize, step: usize) -> Exchange {
        if self.p == 1 {
            return Exchange {
                send_to: 0,
                recv_from: 0,
            };
        }
        let partner = rank ^ (1usize << (step % self.dims));
        Exchange {
            send_to: partner,
            recv_from: partner,
        }
    }

    fn diffusion_steps(&self) -> usize {
        ceil_log2(self.p)
    }

    fn name(&self) -> &'static str {
        "hypercube"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pairwise_and_involutive() {
        let t = Hypercube::new(16);
        for step in 0..8 {
            for r in 0..16 {
                let e = t.exchange(r, step);
                assert_eq!(e.send_to, e.recv_from);
                // partner-of-partner is self
                assert_eq!(t.exchange(e.send_to, step).send_to, r);
            }
        }
    }

    #[test]
    #[should_panic(expected = "power-of-two")]
    fn rejects_non_power_of_two() {
        Hypercube::new(12);
    }

    #[test]
    fn figure6_cube_example() {
        // Figure 6: 8 ranks — step 0 pairs across dim 0, etc.
        let t = Hypercube::new(8);
        assert_eq!(t.exchange(0, 0).send_to, 1);
        assert_eq!(t.exchange(0, 1).send_to, 2);
        assert_eq!(t.exchange(0, 2).send_to, 4);
    }
}
