//! Virtual communication topologies — the paper's §4.3/§4.4.
//!
//! A [`Topology`] answers, for a given rank and gossip step, *who do I
//! send my model/gradients to and who do I receive from*.  GossipGraD's
//! requirements (paper §4.3): (1) O(1) messages per rank per step,
//! (2) **balanced** communication — the per-step exchange pattern is a
//! permutation of the ranks, (3) indirect diffusion of updates to all
//! ranks within ⌈log₂ p⌉ steps, (4) bisection-bandwidth friendly.
//!
//! Implementations:
//! * [`dissemination`] — the paper's primary choice: at step k, rank i
//!   sends to (i + 2^k) mod p and receives from (i − 2^k) mod p.
//! * [`hypercube`]     — pairwise exchange with partner i ⊕ 2^k
//!   (power-of-two p only).
//! * [`ring`]          — used for the asynchronous *sample* shuffle
//!   (§4.5.2), deliberately different from the gradient topology.
//! * [`random`]        — the Jin et al. / Blot et al. baseline whose
//!   imbalance the paper criticises (kept as a comparison point).
//! * [`rotation`]      — §4.5.1 partner rotation: p seeded shuffles of
//!   the communicator, advanced every ⌈log₂ p⌉ steps.
//! * [`twolevel`]      — hierarchical (host-group-aware) schedule: dense
//!   intra-group dissemination, sparse inter-group partners every
//!   `inter_period` steps, rotation applied within groups and to the
//!   group pairings separately (docs/topology.md).

pub mod dissemination;
pub mod hypercube;
pub mod random;
pub mod ring;
pub mod rotation;
pub mod twolevel;

pub use dissemination::Dissemination;
pub use hypercube::Hypercube;
pub use random::RandomGossip;
pub use ring::Ring;
pub use rotation::Rotation;
pub use twolevel::TwoLevel;

/// The peers a rank exchanges with at one gossip step.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Exchange {
    /// Rank we send our update to.
    pub send_to: usize,
    /// Rank we receive an update from.
    pub recv_from: usize,
}

/// A virtual topology over `p` ranks.
pub trait Topology: Send + Sync {
    /// Number of ranks.
    fn size(&self) -> usize;

    /// The exchange performed by `rank` at gossip `step`.
    fn exchange(&self, rank: usize, step: usize) -> Exchange;

    /// Steps after which all ranks have *indirectly* communicated
    /// (⌈log₂ p⌉ for dissemination/hypercube; p−1 for ring).
    fn diffusion_steps(&self) -> usize;

    /// Human-readable name for tables/metrics.
    fn name(&self) -> &'static str;
}

/// Verify the §4.3 "balanced communication" property at `step`:
/// the send pattern must be a permutation with no self-loops (for p > 1),
/// and recv_from must be the inverse of send_to.
pub fn check_balanced(t: &dyn Topology, step: usize) -> Result<(), String> {
    let p = t.size();
    let mut recv_count = vec![0usize; p];
    for r in 0..p {
        let e = t.exchange(r, step);
        if e.send_to >= p || e.recv_from >= p {
            return Err(format!("rank {r} step {step}: peer out of range {e:?}"));
        }
        if p > 1 && e.send_to == r {
            return Err(format!("rank {r} step {step}: self-loop"));
        }
        recv_count[e.send_to] += 1;
        // consistency: if i sends to j, j must expect to receive from i
        let back = t.exchange(e.send_to, step);
        if back.recv_from != r {
            return Err(format!(
                "rank {r} -> {j} but {j} expects recv from {b} (step {step})",
                j = e.send_to,
                b = back.recv_from
            ));
        }
    }
    if recv_count.iter().any(|&c| c != 1) {
        return Err(format!(
            "step {step}: send pattern not a permutation: {recv_count:?}"
        ));
    }
    Ok(())
}

/// Simulate indirect diffusion: start with information only at `origin`,
/// iterate the exchange pattern, return the number of steps until all
/// ranks are reached.  Used by tests to verify the ⌈log₂ p⌉ bound.
pub fn diffusion_time(t: &dyn Topology, origin: usize, max_steps: usize) -> Option<usize> {
    let p = t.size();
    let mut has = vec![false; p];
    has[origin] = true;
    if p == 1 {
        return Some(0);
    }
    for step in 0..max_steps {
        let prev = has.clone();
        for r in 0..p {
            let e = t.exchange(r, step);
            // r sends its (pre-step) knowledge to send_to
            if prev[r] {
                has[e.send_to] = true;
            }
        }
        if has.iter().all(|&b| b) {
            return Some(step + 1);
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::ceil_log2;

    #[test]
    fn dissemination_balanced_all_steps_all_sizes() {
        for p in [1usize, 2, 3, 5, 8, 13, 32, 33, 128] {
            let t = Dissemination::new(p);
            for step in 0..3 * ceil_log2(p).max(1) {
                check_balanced(&t, step).unwrap();
            }
        }
    }

    #[test]
    fn hypercube_balanced_power_of_two() {
        for p in [2usize, 4, 8, 64, 128] {
            let t = Hypercube::new(p);
            for step in 0..2 * ceil_log2(p) {
                check_balanced(&t, step).unwrap();
            }
        }
    }

    #[test]
    fn ring_balanced() {
        for p in [2usize, 3, 7, 32] {
            let t = Ring::new(p);
            for step in 0..5 {
                check_balanced(&t, step).unwrap();
            }
        }
    }

    #[test]
    fn dissemination_diffuses_in_ceil_log2_steps() {
        // the paper's headline claim for the virtual topology (§4.4)
        for p in [2usize, 3, 4, 5, 8, 16, 17, 32, 100, 128] {
            let t = Dissemination::new(p);
            for origin in [0, p / 2, p - 1] {
                let steps = diffusion_time(&t, origin, 4 * p).unwrap();
                assert!(
                    steps <= ceil_log2(p),
                    "p={p} origin={origin}: diffused in {steps} > ⌈log2⌉={}",
                    ceil_log2(p)
                );
            }
        }
    }

    #[test]
    fn hypercube_diffuses_in_log2_steps() {
        for p in [2usize, 4, 8, 32, 128] {
            let t = Hypercube::new(p);
            let steps = diffusion_time(&t, 0, 4 * p).unwrap();
            assert_eq!(steps, ceil_log2(p), "p={p}");
        }
    }

    #[test]
    fn ring_diffusion_is_linear() {
        let p = 16;
        let t = Ring::new(p);
        assert_eq!(diffusion_time(&t, 0, 4 * p).unwrap(), p - 1);
    }

    #[test]
    fn random_gossip_is_unbalanced_somewhere() {
        // the deficiency the paper attributes to Jin/Blot random gossip:
        // some step has a rank receiving 0 or ≥2 messages.
        let t = RandomGossip::new(16, 7);
        let mut saw_imbalance = false;
        for step in 0..64 {
            if check_balanced(&t, step).is_err() {
                saw_imbalance = true;
                break;
            }
        }
        assert!(saw_imbalance, "random gossip unexpectedly balanced");
    }
}
