//! Ring virtual topology — used for the asynchronous distributed *sample*
//! shuffle (paper §4.5.2).  Each rank always sends its just-consumed
//! batch to its right neighbour and receives from its left, giving the
//! fairness property: a sample returns to a rank only after every other
//! rank has held it once (p−1 hops).  Deliberately a different topology
//! from the gradient dissemination exchange.

use super::{Exchange, Topology};

#[derive(Clone, Debug)]
pub struct Ring {
    p: usize,
}

impl Ring {
    pub fn new(p: usize) -> Self {
        assert!(p >= 1);
        Ring { p }
    }
}

impl Topology for Ring {
    fn size(&self) -> usize {
        self.p
    }

    fn exchange(&self, rank: usize, _step: usize) -> Exchange {
        if self.p == 1 {
            return Exchange {
                send_to: 0,
                recv_from: 0,
            };
        }
        Exchange {
            send_to: (rank + 1) % self.p,
            recv_from: (rank + self.p - 1) % self.p,
        }
    }

    fn diffusion_steps(&self) -> usize {
        self.p.saturating_sub(1)
    }

    fn name(&self) -> &'static str {
        "ring"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn neighbours() {
        let t = Ring::new(5);
        assert_eq!(t.exchange(0, 0).send_to, 1);
        assert_eq!(t.exchange(4, 9).send_to, 0);
        assert_eq!(t.exchange(0, 0).recv_from, 4);
    }

    #[test]
    fn sample_returns_after_p_minus_1_hops() {
        // fairness property: following send_to from rank 0 visits all
        // other ranks before returning
        let p = 9;
        let t = Ring::new(p);
        let mut at = 0usize;
        let mut visited = vec![false; p];
        visited[0] = true;
        for hop in 0..p {
            at = t.exchange(at, hop).send_to;
            if at == 0 {
                assert!(visited.iter().all(|&v| v), "returned early at hop {hop}");
                return;
            }
            visited[at] = true;
        }
        panic!("never returned");
    }
}
