//! Two-level, locality-aware gossip schedule (hierarchical fabric).
//!
//! Real clusters are not flat: ranks sharing a host talk over
//! NVLink/PCIe (~100 GB/s) while hosts talk over IB/Aries.  The flat
//! rotation (§4.5.1) scatters partners uniformly, so at p = 1024 nearly
//! every exchange crosses the slow tier.  `TwoLevel` keeps the paper's
//! balanced-permutation property while concentrating traffic on the fast
//! tier: **dense intra-group mixing** on most steps (dissemination
//! *within* each host group) and a **sparse inter-group partner** every
//! `inter_period`-th gossip step (dissemination *between* groups, with a
//! per-round offset shift so updates also cross group-local positions).
//!
//! Rotation is topology-aware: each epoch (every ⌈log₂ p⌉ steps, same
//! cadence as the flat [`Rotation`]) draws — from a per-epoch split of
//! the seed — a fresh shuffle of the virtual positions *within* every
//! group plus a separate shuffle of the group pairings, so partner
//! diversity grows without leaving the fast tier on dense steps.
//!
//! **Flat-identity guarantee** (property-tested below and pinned
//! end-to-end by `tests/topology_hier.rs`): with `group_size == 1`
//! (every rank its own host) or `group_size == p` (one host), the
//! schedule delegates verbatim to today's flat topology — the rotated
//! dissemination when rotation is on, plain dissemination otherwise —
//! so historical runs are bit-identical, `param_hash` included.
//!
//! GoSGD and Elastic Gossip (PAPERS.md) show gossip quality survives
//! restricted/biased partner choice — the license this schedule needs.

use super::{Dissemination, Exchange, Rotation, Topology};
use crate::transport::GroupMap;
use crate::util::{ceil_log2, Rng};
use std::collections::HashMap;
use std::sync::{Arc, Mutex};

/// Per-epoch rotation state: a shuffle of the group pairings plus a
/// shuffle of the virtual positions within each group.
struct Epoch {
    /// group_perm[v] = group id at virtual group position v.
    group_perm: Vec<usize>,
    /// inverse: group_pos[g] = virtual position of group g.
    group_pos: Vec<usize>,
    /// within[g][v] = local offset at virtual position v in group g.
    within: Vec<Vec<usize>>,
    /// inverse: within_pos[g][o] = virtual position of offset o.
    within_pos: Vec<Vec<usize>>,
}

pub struct TwoLevel {
    groups: GroupMap,
    inter_period: usize,
    rotate: bool,
    seed: u64,
    /// The flat schedule, delegated to verbatim in the degenerate cases
    /// (`group_size` 1 or p) and used by the membership layer as the
    /// survivor ordering when a view degrades.
    flat: Rotation<Dissemination>,
    plain: Dissemination,
    /// Dissemination within one group (over `group_size` positions).
    intra: Dissemination,
    /// Dissemination between groups (over `num_groups` positions).
    glevel: Dissemination,
    /// Epoch length in gossip steps — ⌈log₂ p⌉, the flat rotation's
    /// cadence.
    period: usize,
    /// Lazily drawn epochs (pure function of (seed, epoch), so access
    /// order cannot perturb them).
    epochs: Mutex<HashMap<usize, Arc<Epoch>>>,
}

impl TwoLevel {
    /// `p` ranks in groups of `group_size` (must divide `p`), one
    /// inter-group exchange every `inter_period` gossip steps.
    pub fn new(
        p: usize,
        group_size: usize,
        inter_period: usize,
        rotate: bool,
        seed: u64,
    ) -> Self {
        assert!(inter_period >= 1, "inter_period must be >= 1");
        let groups = GroupMap::new(p, group_size);
        TwoLevel {
            groups,
            inter_period,
            rotate,
            seed,
            flat: Rotation::new(Dissemination::new(p), seed),
            plain: Dissemination::new(p),
            intra: Dissemination::new(group_size),
            glevel: Dissemination::new(groups.num_groups()),
            period: ceil_log2(p).max(1),
            epochs: Mutex::new(HashMap::new()),
        }
    }

    /// Degenerate cases route through the flat schedule untouched.
    fn delegates(&self) -> bool {
        self.groups.group_size() == 1 || self.groups.group_size() == self.groups.p()
    }

    pub fn rotates(&self) -> bool {
        self.rotate
    }

    pub fn group_map(&self) -> GroupMap {
        self.groups
    }

    pub fn inter_period(&self) -> usize {
        self.inter_period
    }

    /// Is `step` an inter-group (slow-tier) exchange?
    pub fn is_inter_step(&self, step: usize) -> bool {
        !self.delegates() && step % self.inter_period == 0
    }

    /// The flat rotation's communicator ordering at `step` — the
    /// survivor ordering the membership layer collapses over when a view
    /// degrades (locality is best-effort under faults; the collapsed
    /// schedule's priority is that every survivor pairs with a live
    /// partner).
    pub fn flat_order(&self, step: usize) -> &[usize] {
        self.flat.perm(self.flat.epoch(step))
    }

    /// Which rotation epoch is active at `step` (0 forever when
    /// rotation is off).
    pub fn epoch(&self, step: usize) -> usize {
        if self.rotate {
            (step / self.period) % (self.groups.p() + 1)
        } else {
            0
        }
    }

    fn epoch_state(&self, e: usize) -> Arc<Epoch> {
        let mut map = self.epochs.lock().unwrap();
        if let Some(st) = map.get(&e) {
            return Arc::clone(st);
        }
        let st = Arc::new(self.draw_epoch(e));
        map.insert(e, Arc::clone(&st));
        st
    }

    fn draw_epoch(&self, e: usize) -> Epoch {
        let ng = self.groups.num_groups();
        let gs = self.groups.group_size();
        // epoch 0 is the identity, like the flat rotation: the canonical
        // grouping runs for the first ⌈log₂ p⌉ steps
        let (group_perm, within) = if e == 0 {
            (
                (0..ng).collect::<Vec<_>>(),
                (0..ng).map(|_| (0..gs).collect()).collect::<Vec<Vec<_>>>(),
            )
        } else {
            // independent stream per epoch — a pure function of
            // (seed, e), so lazy access order cannot change the draw
            let mut base = Rng::new(self.seed);
            let mut rng = base.split(e as u64);
            let gp = rng.permutation(ng);
            let w = (0..ng).map(|_| rng.permutation(gs)).collect();
            (gp, w)
        };
        let invert = |perm: &[usize]| {
            let mut inv = vec![0usize; perm.len()];
            for (v, &r) in perm.iter().enumerate() {
                inv[r] = v;
            }
            inv
        };
        Epoch {
            group_pos: invert(&group_perm),
            within_pos: within.iter().map(|w| invert(w)).collect(),
            group_perm,
            within,
        }
    }
}

impl Topology for TwoLevel {
    fn size(&self) -> usize {
        self.groups.p()
    }

    fn exchange(&self, rank: usize, step: usize) -> Exchange {
        if self.delegates() {
            return if self.rotate {
                self.flat.exchange(rank, step)
            } else {
                self.plain.exchange(rank, step)
            };
        }
        let gs = self.groups.group_size();
        let a = self.groups.group_of(rank);
        let base = self.groups.group_base(a);
        let off = rank - base;
        let st = self.epoch_state(self.epoch(step));
        if self.is_inter_step(step) {
            // inter-group step: groups pair via dissemination over the
            // epoch's group shuffle; the per-round offset shift `d`
            // walks the group-local positions so updates cross offsets
            // even when every step is inter (inter_period == 1)
            let round = step / self.inter_period;
            let d = round % gs;
            let gex = self.glevel.exchange(st.group_pos[a], round);
            Exchange {
                send_to: self.groups.group_base(st.group_perm[gex.send_to]) + (off + d) % gs,
                recv_from: self.groups.group_base(st.group_perm[gex.recv_from])
                    + (off + gs - d) % gs,
            }
        } else {
            // dense intra-group step: dissemination within the group
            // over the epoch's within-group shuffle
            let w = &st.within[a];
            let v = st.within_pos[a][off];
            let ex = self.intra.exchange(v, step);
            Exchange {
                send_to: base + w[ex.send_to],
                recv_from: base + w[ex.recv_from],
            }
        }
    }

    fn diffusion_steps(&self) -> usize {
        if self.delegates() {
            return self.flat.diffusion_steps();
        }
        // intra diffusion within groups + one group-level dissemination
        // sweep paced at inter_period
        ceil_log2(self.groups.group_size())
            + self.inter_period * ceil_log2(self.groups.num_groups())
    }

    fn name(&self) -> &'static str {
        "two-level"
    }
}

#[cfg(test)]
mod tests {
    use super::super::{check_balanced, diffusion_time};
    use super::*;

    #[test]
    fn stays_balanced_all_step_kinds() {
        for (p, g, k) in [(8, 2, 1), (8, 2, 4), (8, 4, 2), (16, 4, 4), (12, 3, 3)] {
            let t = TwoLevel::new(p, g, k, true, 42);
            for step in 0..6 * t.period {
                check_balanced(&t, step).unwrap();
            }
            let t = TwoLevel::new(p, g, k, false, 42);
            for step in 0..4 * t.period {
                check_balanced(&t, step).unwrap();
            }
        }
    }

    #[test]
    fn flat_identity_group_size_one_and_p() {
        // the flat-identity guarantee, at the topology level: group_size
        // 1 and p delegate bit-for-bit to today's flat schedule
        let (p, seed) = (16usize, 7u64);
        let rot = Rotation::new(Dissemination::new(p), seed);
        let plain = Dissemination::new(p);
        for g in [1usize, p] {
            let t = TwoLevel::new(p, g, 4, true, seed);
            let f = TwoLevel::new(p, g, 4, false, seed);
            for step in 0..5 * t.period {
                for r in 0..p {
                    assert_eq!(t.exchange(r, step), rot.exchange(r, step), "g={g}");
                    assert_eq!(f.exchange(r, step), plain.exchange(r, step), "g={g}");
                }
            }
            assert_eq!(t.diffusion_steps(), rot.diffusion_steps());
        }
    }

    #[test]
    fn dense_steps_stay_inside_the_group() {
        let t = TwoLevel::new(16, 4, 4, true, 3);
        let gm = t.group_map();
        for step in 0..8 * t.period {
            for r in 0..16 {
                let ex = t.exchange(r, step);
                if t.is_inter_step(step) {
                    assert!(!gm.same_group(r, ex.send_to), "step {step} rank {r}");
                    assert!(!gm.same_group(r, ex.recv_from));
                } else {
                    assert!(gm.same_group(r, ex.send_to), "step {step} rank {r}");
                    assert!(gm.same_group(r, ex.recv_from));
                }
            }
        }
    }

    #[test]
    fn inter_cadence_follows_inter_period() {
        let t = TwoLevel::new(8, 2, 3, true, 1);
        let inter: Vec<usize> = (0..12).filter(|&s| t.is_inter_step(s)).collect();
        assert_eq!(inter, vec![0, 3, 6, 9]);
        // inter_period 1: every step crosses groups
        let t1 = TwoLevel::new(8, 2, 1, true, 1);
        assert!((0..12).all(|s| t1.is_inter_step(s)));
    }

    #[test]
    fn rotation_reshuffles_across_epochs() {
        let t = TwoLevel::new(16, 4, 4, true, 9);
        // same in-epoch step offset, consecutive epochs: at least one
        // rank's partner must move (the shuffles are fresh draws)
        let s0 = 1usize; // dense step in epoch 0
        let s1 = s0 + t.period; // same phase, epoch 1
        assert_ne!(t.epoch(s0), t.epoch(s1));
        let moved = (0..16).any(|r| t.exchange(r, s0) != t.exchange(r, s1));
        assert!(moved, "epoch advance did not reshuffle any partner");
        // without rotation the schedule is epoch-invariant
        let f = TwoLevel::new(16, 4, 4, false, 9);
        for r in 0..16 {
            assert_eq!(f.exchange(r, s0), f.exchange(r, s1));
        }
    }

    #[test]
    fn epoch_draws_are_access_order_independent() {
        let a = TwoLevel::new(16, 4, 4, true, 5);
        let b = TwoLevel::new(16, 4, 4, true, 5);
        // a touches epochs in forward order, b backwards
        let horizon = 4 * a.period;
        let fwd: Vec<Exchange> = (0..horizon).flat_map(|s| (0..16).map(move |r| (r, s)))
            .map(|(r, s)| a.exchange(r, s))
            .collect();
        let bwd: Vec<Exchange> = (0..horizon).rev().flat_map(|s| (0..16).map(move |r| (r, s)))
            .map(|(r, s)| b.exchange(r, s))
            .collect();
        let fwd_rev: Vec<Exchange> = fwd.chunks(16).rev().flatten().copied().collect();
        assert_eq!(fwd_rev, bwd);
    }

    #[test]
    fn updates_diffuse_across_groups_and_offsets() {
        // the offset shift on inter steps means even inter_period == 1
        // (no dense steps at all) eventually reaches every rank
        for (p, g, k) in [(8, 2, 1), (8, 2, 2), (16, 4, 4), (16, 8, 2)] {
            let t = TwoLevel::new(p, g, k, true, 11);
            let horizon = 20 * k * ceil_log2(p).max(1);
            for origin in [0, p / 2, p - 1] {
                assert!(
                    diffusion_time(&t, origin, horizon).is_some(),
                    "p={p} g={g} k={k} origin={origin}: no full diffusion"
                );
            }
        }
    }

    #[test]
    fn flat_order_matches_flat_rotation() {
        let t = TwoLevel::new(16, 4, 4, true, 7);
        let rot = Rotation::new(Dissemination::new(16), 7);
        for step in [0usize, 3, 4, 9, 40] {
            assert_eq!(t.flat_order(step), rot.perm(rot.epoch(step)));
        }
    }
}
