//! Dissemination exchange (paper §4.4.2) — GossipGraD's primary topology.
//!
//! At step k (mod the diffusion horizon), rank i sends to
//! `(i + 2^(k mod ⌈log₂p⌉)) mod p` and receives from
//! `(i + p − 2^(k mod ⌈log₂p⌉)) mod p`.  Unlike hypercube exchange the
//! send and receive partners differ, so each rank *diffuses gradients
//! from two partners per step* — the reason the paper prefers it.
//! Works for any p (not just powers of two).

use super::{Exchange, Topology};
use crate::util::ceil_log2;

#[derive(Clone, Debug)]
pub struct Dissemination {
    p: usize,
    rounds: usize,
}

impl Dissemination {
    pub fn new(p: usize) -> Self {
        assert!(p >= 1);
        Dissemination {
            p,
            rounds: ceil_log2(p).max(1),
        }
    }
}

impl Topology for Dissemination {
    fn size(&self) -> usize {
        self.p
    }

    fn exchange(&self, rank: usize, step: usize) -> Exchange {
        if self.p == 1 {
            return Exchange {
                send_to: 0,
                recv_from: 0,
            };
        }
        let k = step % self.rounds;
        let d = 1usize << k;
        let d = d % self.p; // distances wrap for non-power-of-two p
        let d = if d == 0 { 1 } else { d };
        Exchange {
            send_to: (rank + d) % self.p,
            recv_from: (rank + self.p - d) % self.p,
        }
    }

    fn diffusion_steps(&self) -> usize {
        ceil_log2(self.p)
    }

    fn name(&self) -> &'static str {
        "dissemination"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_paper_formula() {
        // §4.4.2: at step k, p_i sends to (p_i + 2^k) % p
        let t = Dissemination::new(8);
        assert_eq!(
            t.exchange(0, 0),
            Exchange {
                send_to: 1,
                recv_from: 7
            }
        );
        assert_eq!(
            t.exchange(0, 1),
            Exchange {
                send_to: 2,
                recv_from: 6
            }
        );
        assert_eq!(
            t.exchange(0, 2),
            Exchange {
                send_to: 4,
                recv_from: 4
            }
        );
        // period log2(8)=3: step 3 repeats step 0
        assert_eq!(t.exchange(5, 3), t.exchange(5, 0));
    }

    #[test]
    fn send_and_recv_partners_differ_for_p_gt_2() {
        // the "two partners per step" property vs hypercube
        let t = Dissemination::new(8);
        let e = t.exchange(3, 0);
        assert_ne!(e.send_to, e.recv_from);
    }

    #[test]
    fn single_rank_degenerates() {
        let t = Dissemination::new(1);
        assert_eq!(t.exchange(0, 5).send_to, 0);
        assert_eq!(t.diffusion_steps(), 0);
    }

    #[test]
    fn non_power_of_two_never_self_loops() {
        for p in [3usize, 5, 6, 7, 9, 12, 100] {
            let t = Dissemination::new(p);
            for step in 0..3 * t.rounds {
                for r in 0..p {
                    assert_ne!(t.exchange(r, step).send_to, r, "p={p} step={step}");
                }
            }
        }
    }
}
