//! Random gossip — the Jin et al. / Blot et al. baseline (paper Fig 2b).
//!
//! Each rank independently picks a uniformly random partner per step.
//! This is exactly the scheme whose *communication imbalance* and *poor
//! gradient diffusion* the paper criticises (§1, §4.2); we keep it as a
//! measurable baseline.  Deterministic per (seed, step, rank) so
//! experiments replay.

use super::{Exchange, Topology};
use crate::util::Rng;

#[derive(Clone, Debug)]
pub struct RandomGossip {
    p: usize,
    seed: u64,
}

impl RandomGossip {
    pub fn new(p: usize, seed: u64) -> Self {
        assert!(p >= 1);
        RandomGossip { p, seed }
    }

    /// All ranks that send to `rank` at `step` (may be empty or many —
    /// the imbalance).  Used by the random-gossip baseline so every sent
    /// message is actually consumed.
    pub fn senders_to(&self, rank: usize, step: usize) -> Vec<usize> {
        (0..self.p)
            .filter(|&r| r != rank && self.pick(r, step) == rank)
            .collect()
    }

    fn pick(&self, rank: usize, step: usize) -> usize {
        let mut rng = Rng::new(
            self.seed ^ (step as u64).wrapping_mul(0x9E3779B97F4A7C15)
                ^ (rank as u64).wrapping_mul(0xD1B54A32D192ED03),
        );
        if self.p == 1 {
            return 0;
        }
        // uniform over the other p-1 ranks
        let mut t = rng.below(self.p - 1);
        if t >= rank {
            t += 1;
        }
        t
    }
}

impl Topology for RandomGossip {
    fn size(&self) -> usize {
        self.p
    }

    fn exchange(&self, rank: usize, step: usize) -> Exchange {
        // send target is random; "recv_from" must name *some* rank that
        // sends here this step, or ourselves if none does (models the
        // imbalance: a rank may receive 0 or many updates).
        let send_to = self.pick(rank, step);
        let mut recv_from = rank;
        for r in 0..self.p {
            if r != rank && self.pick(r, step) == rank {
                recv_from = r;
                break;
            }
        }
        Exchange { send_to, recv_from }
    }

    fn diffusion_steps(&self) -> usize {
        // expected O(log p) w.h.p., but unbounded worst case; report the
        // coupon-collector-ish bound used for scheduling purposes
        2 * crate::util::ceil_log2(self.p).max(1)
    }

    fn name(&self) -> &'static str {
        "random-gossip"
    }
}

/// Count, for one step, how many messages each rank receives — the
/// imbalance statistic plotted in EXPERIMENTS.md (paper's critique).
pub fn recv_histogram(t: &RandomGossip, step: usize) -> Vec<usize> {
    let mut h = vec![0usize; t.p];
    for r in 0..t.p {
        h[t.pick(r, step)] += 1;
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let a = RandomGossip::new(32, 5);
        let b = RandomGossip::new(32, 5);
        for step in 0..10 {
            for r in 0..32 {
                assert_eq!(a.exchange(r, step), b.exchange(r, step));
            }
        }
    }

    #[test]
    fn never_self_partner() {
        let t = RandomGossip::new(17, 3);
        for step in 0..50 {
            for r in 0..17 {
                assert_ne!(t.exchange(r, step).send_to, r);
            }
        }
    }

    #[test]
    fn histogram_shows_imbalance() {
        // with p=64 the chance of a perfectly balanced random step is ~0
        let t = RandomGossip::new(64, 11);
        let mut max_load = 0;
        for step in 0..20 {
            let h = recv_histogram(&t, step);
            assert_eq!(h.iter().sum::<usize>(), 64);
            max_load = max_load.max(*h.iter().max().unwrap());
        }
        assert!(max_load >= 2, "random gossip suspiciously balanced");
    }
}
