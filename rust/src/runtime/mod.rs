//! PJRT runtime — loads the AOT artifacts emitted by
//! `python/compile/aot.py` and exposes them behind the [`ModelBackend`]
//! trait the coordinator trains against.
//!
//! * [`artifacts`] — `*.meta.json` descriptors + raw init vectors.
//! * [`client`]    — the XLA PJRT CPU client wrapper: HLO text →
//!   `HloModuleProto::from_text_file` → compile → execute (the pattern
//!   from /opt/xla-example/load_hlo).
//!
//! The [`nativenet`](crate::nativenet) backend implements the same trait
//! in pure Rust for artifact-independent tests and very-large-p runs.

pub mod artifacts;
pub mod client;
pub(crate) mod xla_stub;

pub use artifacts::{ArtifactSet, LayerSlice, ModelMeta};
pub use client::PjrtModel;

/// Input batch payload (models take f32 features or i32 token ids).
#[derive(Clone, Debug)]
pub enum BatchData {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl BatchData {
    pub fn len(&self) -> usize {
        match self {
            BatchData::F32(v) => v.len(),
            BatchData::I32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Convert a feature batch to f32 (panics for token batches).
    pub fn as_f32(&self) -> &[f32] {
        match self {
            BatchData::F32(v) => v,
            BatchData::I32(_) => panic!("expected f32 batch"),
        }
    }
}

/// The compute contract between coordinator (L3) and model (L2/L1).
/// Parameters and gradients are flat `f32[N]`; the layer table defines
/// the layer-wise communication granularity.
pub trait ModelBackend: Send {
    /// Total parameter count N.
    fn param_count(&self) -> usize;
    /// Per-layer (name, offset, len) in flat-vector coordinates.
    fn layers(&self) -> &[LayerSlice];
    /// Rows per training batch (static — baked into the artifacts).
    fn batch(&self) -> usize;
    /// Flat input length per batch (rows × feature dim, or rows × seq).
    fn x_len(&self) -> usize;
    /// Number of label rows per batch (B, or B·S for the LM).
    fn labels_len(&self) -> usize;
    /// Number of output classes (vocab size for the LM).
    fn classes(&self) -> usize;
    /// Whether inputs are token ids (i32) rather than features (f32).
    fn x_is_int(&self) -> bool;
    /// Initial parameter vector (identical across ranks, like the
    /// paper's common model initialisation).
    fn init_params(&self) -> Vec<f32>;
    /// Gradients + loss at `params` for one batch.
    fn grad(&self, params: &[f32], x: &BatchData, y: &[i32]) -> (Vec<f32>, f32);
    /// Fused momentum-SGD train step (in-place params/mom). Returns loss.
    fn train_step(
        &self,
        params: &mut [f32],
        mom: &mut [f32],
        x: &BatchData,
        y: &[i32],
        lr: f32,
    ) -> f32;
    /// Apply a momentum-SGD update for externally-produced grads.
    fn apply_update(&self, params: &mut [f32], mom: &mut [f32], grads: &[f32], lr: f32);
    /// Apply the update to one aligned layer slice (the layer-wise
    /// pipeline updates and sends each layer the moment its backprop
    /// slice completes).  Momentum SGD is elementwise, so the default
    /// just delegates to [`apply_update`](Self::apply_update) on the
    /// sub-slices; backends whose update executable is compiled for
    /// full-length buffers (PJRT) override this with a native
    /// elementwise implementation.
    fn apply_update_slice(
        &self,
        params: &mut [f32],
        mom: &mut [f32],
        grads: &[f32],
        lr: f32,
    ) {
        self.apply_update(params, mom, grads, lr);
    }
    /// (loss, correct_count) over one batch.
    fn eval(&self, params: &[f32], x: &BatchData, y: &[i32]) -> (f32, f32);
}
