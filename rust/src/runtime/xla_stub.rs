//! Offline stub of the `xla` crate API surface used by [`super::client`].
//!
//! The real PJRT path needs the `xla` crate plus a compiled
//! `xla_extension` C library, neither of which exists in the offline
//! build environment.  This stub keeps the PJRT client compiling with an
//! identical call surface; every operation that would touch the runtime
//! returns an [`Error`] at run time instead.  All PJRT-dependent tests
//! and benches already gate on `artifacts/*.meta.json` existing, so the
//! stub is never exercised in the default test suite — the native
//! backend ([`crate::nativenet`]) carries all artifact-independent runs.
//!
//! Swapping in the real crate is: delete the `use super::xla_stub as
//! xla;` alias in client.rs and add `xla` to Cargo.toml.

#![allow(dead_code)]

use std::fmt;

#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "{what}: XLA/PJRT runtime is not available in this offline build \
         (src/runtime/xla_stub.rs); use the native backend (use_artifacts=false)"
    )))
}

/// Scalar element types the executables exchange with the host.
pub trait NativeType: Copy {}
impl NativeType for f32 {}
impl NativeType for i32 {}

pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("PjRtClient::cpu")
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }
}

pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable("HloModuleProto::from_text_file")
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[Literal]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

pub struct Literal;

impl Literal {
    pub fn vec1<T: NativeType>(_v: &[T]) -> Literal {
        Literal
    }

    pub fn scalar<T: NativeType>(_v: T) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Ok(Literal)
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        unavailable("Literal::to_vec")
    }

    pub fn get_first_element<T: NativeType>(&self) -> Result<T> {
        unavailable("Literal::get_first_element")
    }

    pub fn copy_raw_to<T: NativeType>(&self, _dst: &mut [T]) -> Result<()> {
        unavailable("Literal::copy_raw_to")
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        unavailable("Literal::to_tuple")
    }
}
