//! Artifact descriptors: `{model}.meta.json` + raw init vectors.

use crate::util::json::Json;
use std::path::{Path, PathBuf};

/// One layer's slice of the flat parameter vector — the granularity of
/// layer-wise asynchronous gradient exchange (paper §5).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct LayerSlice {
    pub name: String,
    pub offset: usize,
    pub len: usize,
}

/// Parsed `{model}.meta.json`.
#[derive(Clone, Debug)]
pub struct ModelMeta {
    pub model: String,
    pub param_count: usize,
    pub batch: usize,
    pub x_shape: Vec<usize>,
    pub x_is_int: bool,
    pub labels_rows: usize,
    pub classes: usize,
    pub momentum: f32,
    pub layers: Vec<LayerSlice>,
}

impl ModelMeta {
    pub fn parse(text: &str) -> Result<ModelMeta, String> {
        let j = Json::parse(text)?;
        let get_usize = |k: &str| {
            j.get(k)
                .and_then(Json::as_usize)
                .ok_or_else(|| format!("meta missing {k}"))
        };
        let layers = j
            .get("layers")
            .and_then(Json::as_arr)
            .ok_or("meta missing layers")?
            .iter()
            .map(|l| {
                Ok(LayerSlice {
                    name: l
                        .get("name")
                        .and_then(Json::as_str)
                        .ok_or("layer missing name")?
                        .to_string(),
                    offset: l
                        .get("offset")
                        .and_then(Json::as_usize)
                        .ok_or("layer missing offset")?,
                    len: l
                        .get("len")
                        .and_then(Json::as_usize)
                        .ok_or("layer missing len")?,
                })
            })
            .collect::<Result<Vec<_>, String>>()?;
        Ok(ModelMeta {
            model: j
                .get("model")
                .and_then(Json::as_str)
                .ok_or("meta missing model")?
                .to_string(),
            param_count: get_usize("param_count")?,
            batch: get_usize("batch")?,
            x_shape: j
                .get("x_shape")
                .and_then(Json::as_arr)
                .ok_or("meta missing x_shape")?
                .iter()
                .filter_map(Json::as_usize)
                .collect(),
            x_is_int: j.get("x_dtype").and_then(Json::as_str) == Some("i32"),
            labels_rows: get_usize("labels_rows")?,
            classes: get_usize("classes")?,
            momentum: j
                .get("momentum")
                .and_then(Json::as_f64)
                .unwrap_or(0.9) as f32,
            layers,
        })
    }

    /// Sanity-check invariants the Rust side depends on.
    pub fn validate(&self) -> Result<(), String> {
        let mut off = 0usize;
        for l in &self.layers {
            if l.offset != off {
                return Err(format!(
                    "layer {} offset {} != running total {off}",
                    l.name, l.offset
                ));
            }
            off += l.len;
        }
        if off != self.param_count {
            return Err(format!(
                "layers cover {off} of {n} params",
                n = self.param_count
            ));
        }
        let x_elems: usize = self.x_shape.iter().product();
        if x_elems == 0 {
            return Err("empty x_shape".into());
        }
        Ok(())
    }
}

/// Paths for one model family's artifacts.
#[derive(Clone, Debug)]
pub struct ArtifactSet {
    pub dir: PathBuf,
    pub meta: ModelMeta,
}

impl ArtifactSet {
    /// Load and validate `{dir}/{model}.meta.json`.
    pub fn load(dir: &Path, model: &str) -> Result<ArtifactSet, String> {
        let meta_path = dir.join(format!("{model}.meta.json"));
        let text = std::fs::read_to_string(&meta_path)
            .map_err(|e| format!("{}: {e}", meta_path.display()))?;
        let meta = ModelMeta::parse(&text)?;
        meta.validate()?;
        if meta.model != model {
            return Err(format!(
                "meta names model {:?}, expected {model:?}",
                meta.model
            ));
        }
        Ok(ArtifactSet {
            dir: dir.to_path_buf(),
            meta,
        })
    }

    pub fn hlo_path(&self, kind: &str) -> PathBuf {
        self.dir
            .join(format!("{kind}_{}.hlo.txt", self.meta.model))
    }

    /// Read the raw little-endian f32 init vector.
    pub fn init_params(&self) -> Result<Vec<f32>, String> {
        let p = self.dir.join(format!("init_{}.f32", self.meta.model));
        let bytes = std::fs::read(&p).map_err(|e| format!("{}: {e}", p.display()))?;
        if bytes.len() != self.meta.param_count * 4 {
            return Err(format!(
                "init file has {} bytes, expected {}",
                bytes.len(),
                self.meta.param_count * 4
            ));
        }
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }
}

/// Default artifacts directory: $GG_ARTIFACTS or ./artifacts.
pub fn default_dir() -> PathBuf {
    std::env::var("GG_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    const META: &str = r#"{
        "model": "mlp", "param_count": 10, "batch": 4,
        "x_shape": [4, 3], "x_dtype": "f32", "labels_rows": 4,
        "classes": 2, "momentum": 0.9,
        "layers": [
            {"name": "fc0", "offset": 0, "len": 6},
            {"name": "fc1", "offset": 6, "len": 4}
        ],
        "artifacts": {}
    }"#;

    #[test]
    fn parse_and_validate() {
        let m = ModelMeta::parse(META).unwrap();
        m.validate().unwrap();
        assert_eq!(m.param_count, 10);
        assert_eq!(m.layers.len(), 2);
        assert!(!m.x_is_int);
    }

    #[test]
    fn validate_rejects_gaps() {
        let mut m = ModelMeta::parse(META).unwrap();
        m.layers[1].offset = 7;
        assert!(m.validate().is_err());
        let mut m2 = ModelMeta::parse(META).unwrap();
        m2.param_count = 11;
        assert!(m2.validate().is_err());
    }

    #[test]
    fn missing_fields_error() {
        assert!(ModelMeta::parse(r#"{"model":"x"}"#).is_err());
    }

    #[test]
    fn real_artifacts_if_present() {
        // integration check against `make artifacts` output
        let dir = default_dir();
        if !dir.join("mlp.meta.json").exists() {
            eprintln!("skipping: no artifacts dir");
            return;
        }
        let a = ArtifactSet::load(&dir, "mlp").unwrap();
        assert_eq!(a.meta.batch, 64);
        let init = a.init_params().unwrap();
        assert_eq!(init.len(), a.meta.param_count);
        assert!(init.iter().all(|v| v.is_finite()));
        assert!(a.hlo_path("grad").exists());
        assert!(a.hlo_path("train_step").exists());
        assert!(a.hlo_path("eval").exists());
    }
}
