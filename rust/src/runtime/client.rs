//! PJRT-backed [`ModelBackend`]: loads HLO text artifacts, compiles them
//! once on the XLA CPU client, and serves grad/train/eval/update/mix
//! calls to all worker threads.
//!
//! Pattern follows /opt/xla-example/load_hlo: `HloModuleProto::
//! from_text_file` → `XlaComputation::from_proto` → `client.compile` →
//! `execute`.  HLO *text* is the interchange format (jax ≥ 0.5 protos
//! carry 64-bit ids that xla_extension 0.5.1 rejects).
//!
//! ## Thread safety
//! The `xla` crate wrappers hold raw pointers and declare no Send/Sync,
//! but the PJRT C API (and the TfrtCpuClient behind it) is documented
//! thread-safe: compiled executables may be executed concurrently from
//! multiple threads.  [`Exe`] asserts that via `unsafe impl`.  Set
//! `GG_SERIALIZE_PJRT=1` to force a global execution mutex when
//! debugging.

use super::artifacts::{ArtifactSet, LayerSlice};
use super::xla_stub as xla;
use super::{BatchData, ModelBackend};
use anyhow::{Context, Result};
use std::path::Path;
use std::sync::Mutex;

/// Thread-safety assertion wrapper (see module docs).
struct Exe(xla::PjRtLoadedExecutable);
unsafe impl Send for Exe {}
unsafe impl Sync for Exe {}

struct ClientBox(#[allow(dead_code)] xla::PjRtClient);
unsafe impl Send for ClientBox {}
unsafe impl Sync for ClientBox {}

pub struct PjrtModel {
    set: ArtifactSet,
    _client: ClientBox,
    grad_exe: Exe,
    train_exe: Exe,
    eval_exe: Exe,
    update_exe: Exe,
    mix_exe: Exe,
    init: Vec<f32>,
    serialize: Option<Mutex<()>>,
}

impl PjrtModel {
    /// Load + compile all executables for `model` from `dir`.
    pub fn load(dir: &Path, model: &str) -> Result<PjrtModel> {
        let set = ArtifactSet::load(dir, model)
            .map_err(anyhow::Error::msg)
            .context("loading artifact meta")?;
        let client = xla::PjRtClient::cpu()?;
        let compile = |kind: &str| -> Result<Exe> {
            let path = set.hlo_path(kind);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("non-utf8 path")?,
            )
            .with_context(|| format!("parsing {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            Ok(Exe(client
                .compile(&comp)
                .with_context(|| format!("compiling {}", path.display()))?))
        };
        let grad_exe = compile("grad")?;
        let train_exe = compile("train_step")?;
        let eval_exe = compile("eval")?;
        let update_exe = compile("update")?;
        let mix_exe = compile("mix")?;
        let init = set.init_params().map_err(anyhow::Error::msg)?;
        let serialize = if std::env::var("GG_SERIALIZE_PJRT").is_ok() {
            Some(Mutex::new(()))
        } else {
            None
        };
        Ok(PjrtModel {
            set,
            _client: ClientBox(client),
            grad_exe,
            train_exe,
            eval_exe,
            update_exe,
            mix_exe,
            init,
            serialize,
        })
    }

    fn x_literal(&self, x: &BatchData) -> Result<xla::Literal> {
        let dims: Vec<i64> =
            self.set.meta.x_shape.iter().map(|&d| d as i64).collect();
        Ok(match x {
            BatchData::F32(v) => xla::Literal::vec1(v).reshape(&dims)?,
            BatchData::I32(v) => xla::Literal::vec1(v).reshape(&dims)?,
        })
    }

    fn run(&self, exe: &Exe, args: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let _guard = self.serialize.as_ref().map(|m| m.lock().unwrap());
        let bufs = exe.0.execute::<xla::Literal>(args)?;
        let tuple = bufs[0][0].to_literal_sync()?;
        Ok(tuple.to_tuple()?)
    }

    /// Pallas gossip-mix executable: (a, b) -> (a+b)/2.  Exposed for the
    /// AOT-vs-native mixing ablation (benches/hotpath.rs).
    pub fn mix(&self, a: &[f32], b: &[f32]) -> Result<Vec<f32>> {
        let la = xla::Literal::vec1(a);
        let lb = xla::Literal::vec1(b);
        let out = self.run(&self.mix_exe, &[la, lb])?;
        Ok(out[0].to_vec::<f32>()?)
    }

    pub fn meta(&self) -> &super::artifacts::ModelMeta {
        &self.set.meta
    }
}

impl ModelBackend for PjrtModel {
    fn param_count(&self) -> usize {
        self.set.meta.param_count
    }

    fn layers(&self) -> &[LayerSlice] {
        &self.set.meta.layers
    }

    fn batch(&self) -> usize {
        self.set.meta.batch
    }

    fn x_len(&self) -> usize {
        self.set.meta.x_shape.iter().product()
    }

    fn labels_len(&self) -> usize {
        self.set.meta.labels_rows
    }

    fn classes(&self) -> usize {
        self.set.meta.classes
    }

    fn x_is_int(&self) -> bool {
        self.set.meta.x_is_int
    }

    fn init_params(&self) -> Vec<f32> {
        self.init.clone()
    }

    fn grad(&self, params: &[f32], x: &BatchData, y: &[i32]) -> (Vec<f32>, f32) {
        let args = vec![
            xla::Literal::vec1(params),
            self.x_literal(x).expect("x literal"),
            xla::Literal::vec1(y),
        ];
        let out = self.run(&self.grad_exe, &args).expect("grad exec");
        let grads = out[0].to_vec::<f32>().expect("grads");
        let loss = out[1].get_first_element::<f32>().expect("loss");
        (grads, loss)
    }

    fn train_step(
        &self,
        params: &mut [f32],
        mom: &mut [f32],
        x: &BatchData,
        y: &[i32],
        lr: f32,
    ) -> f32 {
        let args = vec![
            xla::Literal::vec1(params),
            xla::Literal::vec1(mom),
            self.x_literal(x).expect("x literal"),
            xla::Literal::vec1(y),
            xla::Literal::scalar(lr),
        ];
        let out = self.run(&self.train_exe, &args).expect("train exec");
        out[0]
            .copy_raw_to::<f32>(params)
            .expect("copy params");
        out[1].copy_raw_to::<f32>(mom).expect("copy mom");
        out[2].get_first_element::<f32>().expect("loss")
    }

    fn apply_update(
        &self,
        params: &mut [f32],
        mom: &mut [f32],
        grads: &[f32],
        lr: f32,
    ) {
        let args = vec![
            xla::Literal::vec1(params),
            xla::Literal::vec1(mom),
            xla::Literal::vec1(grads),
            xla::Literal::scalar(lr),
        ];
        let out = self.run(&self.update_exe, &args).expect("update exec");
        out[0].copy_raw_to::<f32>(params).expect("copy params");
        out[1].copy_raw_to::<f32>(mom).expect("copy mom");
    }

    fn apply_update_slice(
        &self,
        params: &mut [f32],
        mom: &mut [f32],
        grads: &[f32],
        lr: f32,
    ) {
        // the compiled update executable takes full-length buffers, so
        // layer slices go through the native elementwise momentum-SGD
        // kernel with the artifact's momentum coefficient
        crate::nativenet::ops::sgd_momentum(
            params,
            mom,
            grads,
            lr,
            self.set.meta.momentum,
        );
    }

    fn eval(&self, params: &[f32], x: &BatchData, y: &[i32]) -> (f32, f32) {
        let args = vec![
            xla::Literal::vec1(params),
            self.x_literal(x).expect("x literal"),
            xla::Literal::vec1(y),
        ];
        let out = self.run(&self.eval_exe, &args).expect("eval exec");
        let loss = out[0].get_first_element::<f32>().expect("loss");
        let correct = out[1].get_first_element::<f32>().expect("correct");
        (loss, correct)
    }
}

#[cfg(test)]
mod tests {
    use super::super::artifacts::default_dir;
    use super::*;

    fn load_mlp() -> Option<PjrtModel> {
        let dir = default_dir();
        if !dir.join("mlp.meta.json").exists() {
            eprintln!("skipping: run `make artifacts` first");
            return None;
        }
        Some(PjrtModel::load(&dir, "mlp").expect("load mlp artifacts"))
    }

    #[test]
    fn grad_and_eval_shapes() {
        let Some(m) = load_mlp() else { return };
        let params = m.init_params();
        let x = BatchData::F32(vec![0.1; m.x_len()]);
        let y: Vec<i32> = (0..m.labels_len() as i32).map(|i| i % 10).collect();
        let (g, loss) = m.grad(&params, &x, &y);
        assert_eq!(g.len(), m.param_count());
        assert!(loss.is_finite() && loss > 0.0, "loss={loss}");
        let (eloss, correct) = m.eval(&params, &x, &y);
        assert!(eloss.is_finite());
        assert!((0.0..=m.batch() as f32).contains(&correct));
    }

    #[test]
    fn train_step_reduces_loss() {
        let Some(m) = load_mlp() else { return };
        let mut params = m.init_params();
        let mut mom = vec![0.0f32; m.param_count()];
        let mut rng = crate::util::Rng::new(3);
        let x = BatchData::F32(
            (0..m.x_len()).map(|_| rng.normal_f32() * 0.5).collect(),
        );
        let y: Vec<i32> =
            (0..m.labels_len()).map(|_| rng.below(10) as i32).collect();
        let l0 = m.train_step(&mut params, &mut mom, &x, &y, 0.05);
        let mut last = l0;
        for _ in 0..4 {
            last = m.train_step(&mut params, &mut mom, &x, &y, 0.05);
        }
        assert!(last < l0, "loss did not drop: {l0} -> {last}");
    }

    #[test]
    fn mix_artifact_averages() {
        let Some(m) = load_mlp() else { return };
        let n = m.param_count();
        let a = vec![1.0f32; n];
        let b = vec![3.0f32; n];
        let mixed = m.mix(&a, &b).unwrap();
        assert!(mixed.iter().all(|&v| (v - 2.0).abs() < 1e-6));
    }

    #[test]
    fn update_matches_train_step_decomposition() {
        // grad + apply_update must equal the fused train_step
        let Some(m) = load_mlp() else { return };
        let mut p1 = m.init_params();
        let mut m1 = vec![0.0f32; m.param_count()];
        let mut p2 = p1.clone();
        let mut m2 = m1.clone();
        let mut rng = crate::util::Rng::new(5);
        let x = BatchData::F32(
            (0..m.x_len()).map(|_| rng.normal_f32() * 0.5).collect(),
        );
        let y: Vec<i32> =
            (0..m.labels_len()).map(|_| rng.below(10) as i32).collect();
        m.train_step(&mut p1, &mut m1, &x, &y, 0.1);
        let (g, _) = m.grad(&p2.clone(), &x, &y);
        m.apply_update(&mut p2, &mut m2, &g, 0.1);
        let max_diff = p1
            .iter()
            .zip(&p2)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(max_diff < 1e-5, "fused vs decomposed diff {max_diff}");
    }
}
