//! # GossipGraD — gossip-communication-based asynchronous gradient descent
//!
//! Full-system reproduction of *GossipGraD: Scalable Deep Learning using
//! Gossip Communication based Asynchronous Gradient Descent* (Daily,
//! Vishnu, Siegel, Warfel, Amatya — PNNL, 2018) as a three-layer
//! Rust + JAX + Pallas stack.
//!
//! Layer map (see `DESIGN.md` for the full inventory):
//!
//! * [`topology`] — virtual communication topologies: dissemination,
//!   hypercube, ring, random gossip, plus communicator **rotation**
//!   (paper §4.3–4.5).
//! * [`transport`] — MPI-like message substrate, split into a **link
//!   layer** (`transport::link`: delivery only, behind the `Link`
//!   trait — in-process mailboxes or one-process-per-rank TCP frames,
//!   `transport::tcp`, docs/transport.md) and an **accounting layer**
//!   (non-blocking isend/irecv/test_all/wait_all, the α–β cost model
//!   (`simnet`) standing in for InfiniBand/Aries, the hidden/exposed
//!   overlap ledger).  Runs under a wall clock (default) or a
//!   deterministic virtual clock (`transport::clock`,
//!   docs/virtual-time.md) that scales measured runs to p = 256+ in
//!   seconds with bit-reproducible timings.
//! * [`collectives`] — all-reduce algorithms (recursive doubling,
//!   binomial tree, ring) built on the transport as per-round state
//!   machines under a non-blocking engine (`IAllreduce`:
//!   post/progress/test/wait) with a modeled comm-progress thread on
//!   the virtual fabric; the SGD/AGD baselines.
//! * [`coordinator`] — the paper's contribution: the GossipGraD engine
//!   (partner selection + pairwise mixing + rotation + ring sample
//!   shuffle + layer-wise asynchronous exchange) and every baseline it
//!   is compared against (sync SGD, AGD, periodic-AGD, random gossip,
//!   parameter server).
//! * [`runtime`] — PJRT executor: loads `artifacts/*.hlo.txt` produced
//!   by `python/compile/aot.py` and runs them on the XLA CPU client.
//! * [`nativenet`] — pure-Rust compute backend (same model families)
//!   used for large-p experiments and artifact-independent tests.
//! * [`data`] — synthetic datasets (MNIST/CIFAR analogs, token corpus),
//!   sharding, ring shuffle buffers.
//! * [`sim`] — discrete-event scale simulator regenerating the paper's
//!   128-GPU efficiency tables from calibrated per-step costs.
//! * [`exp`] — declarative experiment engine: scenario [`exp::Grid`]s
//!   executed by a work-stealing [`exp::Engine`] on parallel host
//!   threads, with content-hash result caching and JSON/CSV artifact
//!   emission (docs/experiments.md); drives the `sweep` subcommand and
//!   the figure/table benches.
//! * [`codec`] — wire codecs: the typed [`codec::Payload`] every
//!   transport message carries, with f32/bf16/int8/top-k encoders and
//!   per-destination error-feedback residuals; compressed bytes are
//!   what the fabric charges (docs/wire-codecs.md).
//! * [`pool`] — the shared [`pool::BufferPool`] of reusable payload
//!   buffers behind every hot send/receive path, with the
//!   allocation-counting hook that gates the steady-state
//!   zero-allocation property (docs/perf.md).
//! * [`membership`] — first-class membership: seeded [`membership::FaultPlan`]s,
//!   epoch-numbered alive-set [`membership::View`]s with deterministic
//!   transitions, survivor partner routing and the late-rank bootstrap
//!   protocol (docs/fault-tolerance.md).
//! * [`sched`] — the cooperative rank scheduler: virtual-clock rank
//!   bodies as stackful coroutines multiplexed over `--sim-threads`
//!   worker threads via the transport's park/wake seam, so p = 1024
//!   scenarios stop costing 1024 OS threads (docs/perf.md).
//! * [`metrics`], [`config`], [`util`] — supporting infrastructure
//!   (the offline environment has no clap/serde/criterion/proptest, so
//!   `util` carries small hand-rolled equivalents).

pub mod codec;
pub mod collectives;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod exp;
pub mod membership;
pub mod metrics;
pub mod nativenet;
pub mod pool;
pub mod runtime;
pub mod sched;
pub mod sim;
pub mod topology;
pub mod transport;
pub mod util;
