#!/usr/bin/env python3
"""CI bench regression gate: diff a fresh BENCH_*.json against the
committed baseline.

Usage:
    python3 tools/bench_diff.py BASELINE.json CURRENT.json [--threshold 0.25]

Gating policy (docs/perf.md):

* ``allocs``  — hard gate, lower is better.  A baseline of 0 means the
  zero-allocation steady-state invariant: ANY current allocation fails.
  A nonzero baseline fails when current exceeds baseline * (1 + threshold).
* ``threads`` — hard gate, lower is better (same rule): peak OS thread
  count of the rank scheduler's bounded pool (BENCH_sweep_scale.json) —
  a regression here means thread-per-rank execution crept back in.
* ``gbs``     — hard gate, higher is better.  Fails when current drops
  below baseline * (1 - threshold).
* every other metric (``median_secs``, ...) — advisory only: printed,
  never fails the build.  Wall timings on shared CI runners are too
  noisy to gate; bandwidth floors are set conservatively low instead.

Entries present in the baseline but missing from the current report fail
(a silently dropped benchmark is a regression in coverage).  Entries new
in the current report are reported but pass — commit a refreshed
baseline to start gating them.

stdlib only; exit code 0 = pass, 1 = regression.
"""

import argparse
import json
import sys

HARD_LOWER_IS_BETTER = ("allocs", "threads")
HARD_HIGHER_IS_BETTER = ("gbs",)


def load(path):
    with open(path) as f:
        doc = json.load(f)
    if "entries" not in doc:
        sys.exit(f"bench_diff: {path}: no 'entries' key")
    return doc


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("baseline")
    ap.add_argument("current")
    ap.add_argument(
        "--threshold",
        type=float,
        default=0.25,
        help="fractional regression allowed on gated metrics (default 0.25)",
    )
    args = ap.parse_args()

    base = load(args.baseline)
    cur = load(args.current)
    if base.get("bench") != cur.get("bench"):
        sys.exit(
            f"bench_diff: bench name mismatch: "
            f"{base.get('bench')!r} vs {cur.get('bench')!r}"
        )

    failures = []
    rows = []
    for entry, bmetrics in sorted(base["entries"].items()):
        cmetrics = cur["entries"].get(entry)
        if cmetrics is None:
            failures.append(f"{entry}: missing from current report")
            continue
        for key, bval in sorted(bmetrics.items()):
            cval = cmetrics.get(key)
            if cval is None:
                failures.append(f"{entry}.{key}: metric missing from current report")
                continue
            if key in HARD_LOWER_IS_BETTER:
                limit = bval * (1.0 + args.threshold)
                ok = cval == 0 if bval == 0 else cval <= limit
                gate = "GATE"
            elif key in HARD_HIGHER_IS_BETTER:
                limit = bval * (1.0 - args.threshold)
                ok = cval >= limit
                gate = "GATE"
            else:
                ok = True
                gate = "info"
            status = "ok" if ok else "FAIL"
            rows.append((entry, key, gate, bval, cval, status))
            if not ok:
                failures.append(
                    f"{entry}.{key}: baseline {bval:g}, current {cval:g} "
                    f"(threshold {args.threshold:.0%})"
                )

    for entry in sorted(set(cur["entries"]) - set(base["entries"])):
        rows.append((entry, "-", "new", "-", "-", "ungated"))

    w = max((len(r[0]) for r in rows), default=10)
    print(f"{'entry':<{w}}  {'metric':<12} {'kind':<5} {'baseline':>12} {'current':>12}  status")
    for entry, key, gate, bval, cval, status in rows:
        b = f"{bval:.4g}" if isinstance(bval, float) else str(bval)
        c = f"{cval:.4g}" if isinstance(cval, float) else str(cval)
        print(f"{entry:<{w}}  {key:<12} {gate:<5} {b:>12} {c:>12}  {status}")

    if failures:
        print(f"\nbench_diff: {len(failures)} regression(s):", file=sys.stderr)
        for f in failures:
            print(f"  {f}", file=sys.stderr)
        sys.exit(1)
    print("\nbench_diff: pass")


if __name__ == "__main__":
    main()
