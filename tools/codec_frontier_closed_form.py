#!/usr/bin/env python3
"""Closed-form codec x gossip_period frontier artifact.

Mirrors the gossip arithmetic of rust/src/sim/efficiency.rs
(step_time_with_codec, Schedule::Gossip) for the codec-frontier grid
(`gossipgrad sweep --preset codec-frontier-1024`): LeNet3 at device
speed 4, alpha = 200 us, beta = 1 / 0.5 GB/s, p = 1024, codecs
{f32, bf16, int8, topk} x gossip periods {1, 2, 4}.

This is the *analytic* frontier committed as
BENCH_codec_frontier.{json,csv}; the *measured* twin (with real
numerics, param hashes and eval accuracy) is produced by the CI
"codec frontier" step from the same preset and must agree on the
ordering: bf16 > f32 efficiency at every period.  Closed-form rows
carry no param_hash / accuracy columns on purpose — this model times
the wire, it does not train.

Run from the repo root:  python3 tools/codec_frontier_closed_form.py
"""

import csv
import json
import math
import os

# -- fabric + workload constants (codec-frontier preset) ---------------
P = 1024
ALPHA = 200e-6          # per-message latency, seconds
BETA = 1.0 / 0.5e9      # seconds per byte (0.5 GB/s)
DEVICE_SPEED = 4.0
PERIODS = [1, 2, 4]
CODECS = ["f32", "bf16", "int8", "topk"]
INT8_CHUNK = 256        # codec::INT8_CHUNK
TOPK_KEEP = 16          # codec::top_k keeps n/16 coordinates
MIX_BW = 500.0e9        # device-memory mixing pass, bytes/s (2R+1W -> 3x)

# Workload::lenet3(4.0): t = 0.025 / speed, fwd:bwd = 1:2,
# layer bytes in backprop-completion order (output layer first)
T_TOTAL = 0.025 / DEVICE_SPEED
T_FWD = T_TOTAL / 3.0
T_BWD = 2.0 * T_TOTAL / 3.0
LAYER_BYTES = [120_000, 1_600_000, 400_000]
MODEL_BYTES = sum(LAYER_BYTES)


def wire_bytes(codec: str, dense_bytes: int) -> int:
    """Codec::wire_bytes_for on the rank-side Encoder path (gossip)."""
    n = dense_bytes // 4
    if codec == "f32":
        return 4 * n
    if codec == "bf16":
        return 2 * n
    if codec == "int8":
        return n + 4 * math.ceil(n / INT8_CHUNK)
    if codec == "topk":
        return 8 * max(1, n // TOPK_KEEP)
    raise ValueError(codec)


def grad_ready_times():
    """Workload::grad_ready_times: fwd + prefix sums of bwd slices."""
    t, out = T_FWD, []
    for b in LAYER_BYTES:
        t += T_BWD * b / MODEL_BYTES
        out.append(t)
    return out


def nic_drain(msgs):
    """Serialize (ready, wire_time) messages on one NIC."""
    free = 0.0
    for ready, wire in sorted(msgs):
        free = max(free, ready) + wire
    return free


def gossip_step(codec: str):
    """sim::efficiency step_time_with_codec, Schedule::Gossip."""
    ready = grad_ready_times()
    msgs = [
        (r, ALPHA + wire_bytes(codec, b) * BETA)
        for r, b in zip(ready, LAYER_BYTES)
    ]
    comm_done = nic_drain(msgs)
    mix = 3.0 * MODEL_BYTES / MIX_BW
    t_compute = T_FWD + T_BWD
    return t_compute, max(t_compute, comm_done) + mix


def main():
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    rows = []
    for codec in CODECS:
        for period in PERIODS:
            t_compute, t_comm_step = gossip_step(codec)
            # a period-k window: k-1 compute-only steps + 1 exchange step
            tot_step = (period - 1) * t_compute + t_comm_step
            tot_comp = period * t_compute
            rows.append(
                {
                    "codec": codec,
                    "gossip_period": period,
                    "ranks": P,
                    "wire_bytes_per_exchange": sum(
                        wire_bytes(codec, b) for b in LAYER_BYTES
                    ),
                    "dense_bytes_per_exchange": MODEL_BYTES,
                    "mean_step_secs": tot_step / period,
                    "mean_efficiency_pct": 100.0 * tot_comp / tot_step,
                    "exposed_comm_secs": max(0.0, tot_step - tot_comp)
                    / period,
                }
            )
    artifact = {
        "kind": "closed-form",
        "note": (
            "analytic codec x gossip_period frontier from "
            "sim::efficiency::step_time_with_codec (Schedule::Gossip); "
            "the measured twin is CI's `sweep --preset "
            "codec-frontier-1024` artifact — see docs/wire-codecs.md"
        ),
        "model": {
            "workload": "lenet3",
            "device_speed": DEVICE_SPEED,
            "alpha_secs": ALPHA,
            "beta_secs_per_byte": BETA,
            "ranks": P,
            "layer_bytes": LAYER_BYTES,
        },
        "scenarios": rows,
    }
    json_path = os.path.join(root, "BENCH_codec_frontier.json")
    with open(json_path, "w") as f:
        json.dump(artifact, f, indent=2)
        f.write("\n")
    csv_path = os.path.join(root, "BENCH_codec_frontier.csv")
    with open(csv_path, "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=list(rows[0].keys()))
        w.writeheader()
        w.writerows(rows)
    eff = {(r["codec"], r["gossip_period"]): r["mean_efficiency_pct"] for r in rows}
    for period in PERIODS:
        assert eff[("bf16", period)] >= eff[("f32", period)], (period, eff)
    print(f"wrote {json_path} and {csv_path}")
    for r in rows:
        print(
            f"  {r['codec']:>5} period={r['gossip_period']}: "
            f"{r['mean_efficiency_pct']:.2f}% eff, "
            f"{r['wire_bytes_per_exchange']} wire B"
        )


if __name__ == "__main__":
    main()
