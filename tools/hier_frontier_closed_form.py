#!/usr/bin/env python3
"""Closed-form flat-vs-hierarchical gossip frontier artifact.

Mirrors the two-tier gossip arithmetic of rust/src/sim/efficiency.rs
(gossip_step_time_with_topology / avg_gossip_efficiency_with_topology)
for the hier-frontier gate: LeNet3 at device speed 40, p = 1024 ranks
in 128 modeled 8-rank host groups, NVLink-class links inside a group
(0.5 us, 100 GB/s), a slow inter-group tier (alpha = 200 us,
0.5 GB/s), averaged over a 64-step window.

Three rows:
  * group_size 1                  -- flat rotation, every hop inter-tier
  * group_size 8, inter_period 1  -- hierarchical costs, topology-blind
                                     cadence (every exchange crosses)
  * group_size 8, inter_period 4  -- the locality-aware two-level
                                     schedule (3 intra steps : 1 inter)

This is the *analytic* arm committed as BENCH_hier_frontier.{json,csv};
the *measured* twin (real coordinator + virtual clock) is CI's
`sweep --preset hier-frontier-1024` artifact, and both must clear the
same gate: the two-level schedule beats the flat fabric by >= 1.5x on
mean step time.  Closed-form rows carry no param_hash on purpose —
this model times the wire, it does not train (docs/topology.md).

Run from the repo root:  python3 tools/hier_frontier_closed_form.py
"""

import csv
import json
import os

# -- fabric + workload constants (hier-frontier gate) ------------------
P = 1024
GROUP_SIZE = 8                   # 128 modeled hosts
INTER_PERIOD = 4
STEPS = 64                       # averaging window (multiple of period)
GATE = 1.5                       # required flat/two-level step ratio

INTER_ALPHA = 200e-6             # inter-group latency, seconds
INTER_BETA = 1.0 / 0.5e9         # inter-group seconds per byte
INTRA_ALPHA = 0.5e-6             # CostModel::nvlink()
INTRA_BETA = 1.0 / 100.0e9
MIX_BW = 500.0e9                 # device-memory mixing pass (2R+1W -> 3x)

# Workload::lenet3(40.0): t = 0.025 / speed, fwd:bwd = 1:2,
# layer bytes in backprop-completion order (output layer first)
DEVICE_SPEED = 40.0
T_TOTAL = 0.025 / DEVICE_SPEED
T_FWD = T_TOTAL / 3.0
T_BWD = 2.0 * T_TOTAL / 3.0
LAYER_BYTES = [120_000, 1_600_000, 400_000]
MODEL_BYTES = sum(LAYER_BYTES)


def grad_ready_times():
    """Workload::grad_ready_times: fwd + prefix sums of bwd slices."""
    t, out = T_FWD, []
    for b in LAYER_BYTES:
        t += T_BWD * b / MODEL_BYTES
        out.append(t)
    return out


def nic_drain(msgs):
    """Serialize (ready, wire_time) messages on one NIC."""
    free = 0.0
    for ready, wire in sorted(msgs):
        free = max(free, ready) + wire
    return free


def step_time(group_size: int, inter_period: int, step_idx: int):
    """sim::efficiency::gossip_step_time_with_topology."""
    two_level = 1 < group_size < P
    inter_step = (
        step_idx % max(inter_period, 1) == 0 if two_level else group_size == 1
    )
    alpha, beta = (
        (INTER_ALPHA, INTER_BETA) if inter_step else (INTRA_ALPHA, INTRA_BETA)
    )
    msgs = [
        (r, alpha + b * beta)
        for r, b in zip(grad_ready_times(), LAYER_BYTES)
    ]
    comm_done = nic_drain(msgs)
    mix = 3.0 * MODEL_BYTES / MIX_BW
    t_compute = T_FWD + T_BWD
    return t_compute, max(t_compute, comm_done) + mix


def window_avg(group_size: int, inter_period: int):
    """avg_gossip_efficiency_with_topology: window rounded up to a
    whole number of inter periods so every row sees the same inter:intra
    duty cycle."""
    k = max(inter_period, 1)
    steps = ((STEPS + k - 1) // k) * k
    tot_c = tot_s = 0.0
    for i in range(steps):
        c, s = step_time(group_size, inter_period, i)
        tot_c += c
        tot_s += s
    return tot_c / steps, tot_s / steps


def main():
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    arms = [
        ("flat", 1, 1),
        ("hier-costs-flat-schedule", GROUP_SIZE, 1),
        ("two-level", GROUP_SIZE, INTER_PERIOD),
    ]
    rows = []
    for name, g, ip in arms:
        t_compute, t_step = window_avg(g, ip)
        rows.append(
            {
                "schedule": name,
                "ranks": P,
                "group_size": g,
                "num_groups": P // g,
                "inter_period": ip,
                "mean_step_secs": t_step,
                "mean_efficiency_pct": 100.0 * t_compute / t_step,
                "exposed_comm_secs": max(0.0, t_step - t_compute),
            }
        )
    flat = rows[0]["mean_step_secs"]
    blind = rows[1]["mean_step_secs"]
    hier = rows[2]["mean_step_secs"]
    ratio = flat / hier
    artifact = {
        "kind": "closed-form",
        "note": (
            "analytic flat-vs-hierarchical gossip frontier from "
            "sim::efficiency::avg_gossip_efficiency_with_topology; the "
            "measured twin is CI's `sweep --preset hier-frontier-1024` "
            "artifact — see docs/topology.md"
        ),
        "model": {
            "workload": "lenet3",
            "device_speed": DEVICE_SPEED,
            "ranks": P,
            "group_size": GROUP_SIZE,
            "inter_period": INTER_PERIOD,
            "steps": STEPS,
            "inter_alpha_secs": INTER_ALPHA,
            "inter_beta_secs_per_byte": INTER_BETA,
            "intra_alpha_secs": INTRA_ALPHA,
            "intra_beta_secs_per_byte": INTRA_BETA,
            "layer_bytes": LAYER_BYTES,
        },
        "flat_over_two_level_step_ratio": ratio,
        "gate_min_ratio": GATE,
        "scenarios": rows,
    }
    json_path = os.path.join(root, "BENCH_hier_frontier.json")
    with open(json_path, "w") as f:
        json.dump(artifact, f, indent=2)
        f.write("\n")
    csv_path = os.path.join(root, "BENCH_hier_frontier.csv")
    with open(csv_path, "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=list(rows[0].keys()))
        w.writeheader()
        w.writerows(rows)
    # the gate: the locality-aware schedule must beat flat rotation, and
    # the win must come from the schedule (the topology-blind middle arm
    # must NOT clear the gate — its every exchange still crosses hosts)
    assert ratio >= GATE, (ratio, GATE, rows)
    assert flat / blind < GATE, (flat / blind, rows)
    print(f"wrote {json_path} and {csv_path}")
    for r in rows:
        print(
            f"  {r['schedule']:>24} g={r['group_size']:<4} "
            f"k={r['inter_period']}: {1e3 * r['mean_step_secs']:.3f} ms/step, "
            f"{r['mean_efficiency_pct']:.1f}% eff"
        )
    print(f"flat / two-level step time = {ratio:.2f}x (gate {GATE}x)")


if __name__ == "__main__":
    main()
